"""musicgen-medium [audio] — decoder-only over EnCodec tokens.
[arXiv:2306.05284; hf]

48L d_model=1536 24H (kv=24 => MHA) d_ff=6144 vocab=2048 (EnCodec codebook).
Backbone only per the assignment: the EnCodec/delay-pattern frontend is a
STUB — ``input_specs()`` provides precomputed frame embeddings (B,S,d) and
aligned next-frame labels (B,S).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    embed_inputs=False,  # frontend stub supplies embeddings
    pos_type="sinusoidal",
    mlp_type="gelu",
    norm_type="layernorm",
    norm_eps=1e-5,
    use_bias=True,
    tie_embeddings=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
    layout="cp_fsdp",
    remat="full",
    num_microbatches=1,
)
