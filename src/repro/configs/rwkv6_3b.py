"""rwkv6-3b (Finch) [ssm] — attention-free, data-dependent decay linear
attention + squared-ReLU channel-mix. [arXiv:2404.05892; hf]

32L d_model=2560 (40 heads of 64) d_ff=8960 vocab=65536.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # d_model / rwkv_head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    mixer_pattern=("rwkv",),
    pos_type="none",
    norm_type="layernorm",
    norm_eps=1e-5,
    rwkv_head_dim=64,
    tie_embeddings=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
    layout="tp_ffn",
    remat="full",
    num_microbatches=2,
)
