"""Assigned input shapes. Every (arch x shape) pair is one dry-run cell.

train_*   lower ``train_step``; prefill_* lower ``prefill``;
decode_* / long_* lower ``decode_step`` (one token against a seq_len cache).

``long_500k`` requires a sub-quadratic sequence path: it RUNS for
ssm/hybrid/window-bounded-attention archs and is SKIPPED for pure
full-attention archs (see DESIGN.md §5 — the skip is part of the assignment).
"""

from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeCfg("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCfg("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCfg("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCfg("long_500k", "decode", 524288, 1),
}

# archs with a sub-quadratic long-context path (SSM / hybrid / sliding-window)
_LONG_OK = {
    "rwkv6-3b",  # ssm: O(1) state
    "jamba-1.5-large-398b",  # hybrid: mamba + 1:8 attention (seq-sharded KV)
    "mixtral-8x22b",  # SWA(4096): rolling window cache
    "starcoder2-3b",  # SWA(4096): rolling window cache
    "gemma2-2b",  # alternating local(4096)/global; globals use seq-sharded KV
}


def cell_applicable(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in _LONG_OK
    return True
