"""Beyond-paper optimized run configurations (EXPERIMENTS.md §Perf).

The per-arch config files keep the paper-faithful baseline; these overrides
are the hillclimbed variants. Selected per (arch, step-kind) — e.g.:

    cfg = get_config("deepseek-coder-33b").replace(
        **OPTIMIZED["deepseek-coder-33b"]["train"])
    rules = layout_rules(mesh, cfg, "train", layout=cfg.layout)

Measured effects (single-pod, see §Perf):
  deepseek train_4k : 46.5 s -> 5.8 s step bound (roofline frac 0.10 -> 0.71)
  rwkv6    train_4k : 12-16x (tp_ffn replicated the recurrence across the
                      model axis; pure-FSDP removes it)
  mixtral decode_32k: 361 ms -> 10.5 ms per token (weight-stationary decode)
"""

OPTIMIZED = {
    "deepseek-coder-33b": {
        "train": dict(layout="fsdp", num_microbatches=1, flash_vjp=True),
        "decode": dict(layout="decode_ws"),
    },
    "yi-34b": {  # same shape/family as deepseek
        "train": dict(layout="fsdp", num_microbatches=1, flash_vjp=True),
        "decode": dict(layout="decode_ws"),
    },
    "rwkv6-3b": {
        "train": dict(layout="fsdp", num_microbatches=1),
    },
    "mixtral-8x22b": {
        "train": dict(num_microbatches=2, flash_vjp=True),
        "decode": dict(layout="decode_ws"),
    },
    "llama4-scout-17b-a16e": {
        "train": dict(num_microbatches=1, flash_vjp=True),
        "decode": dict(layout="decode_ws"),
    },
    "jamba-1.5-large-398b": {
        "train": dict(num_microbatches=2),
        "decode": dict(layout="decode_ws"),
    },
    "musicgen-medium": {
        "train": dict(layout="fsdp", num_microbatches=1, flash_vjp=True),
    },
    "gemma2-2b": {
        "train": dict(flash_vjp=True),
    },
    "starcoder2-3b": {
        "train": dict(flash_vjp=True),
    },
    "paligemma-3b": {
        "train": dict(flash_vjp=True),
    },
}
