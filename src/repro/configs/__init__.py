from repro.configs.registry import (  # noqa: F401
    ARCH_IDS,
    get_config,
    smoke_config,
)
from repro.configs.shapes import SHAPES, ShapeCfg, cell_applicable  # noqa: F401
