"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig, block_structure

_MODULES: Dict[str, str] = {
    "deepseek-coder-33b": "repro.configs.deepseek_coder_33b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "yi-34b": "repro.configs.yi_34b",
    "gemma2-2b": "repro.configs.gemma2_2b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "musicgen-medium": "repro.configs.musicgen_medium",
    "llama4-scout-17b-a16e": "repro.configs.llama4_scout_17b_a16e",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "paligemma-3b": "repro.configs.paligemma_3b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch_id]).CONFIG


def smoke_config(arch_id: str) -> ModelConfig:
    """Reduced config of the same family: same layer patterns / norm / MoE
    structure, tiny widths. Used by CPU smoke tests (real allocation + one
    forward/train step); the full configs are only ever lowered abstractly."""
    c = get_config(arch_id)
    block, _, _ = block_structure(c)
    d_model = 128
    head_dim = 32
    num_heads = 4
    num_kv_heads = min(c.num_kv_heads, 2) if c.num_kv_heads < c.num_heads else num_heads
    if c.num_kv_heads == 1:
        num_kv_heads = 1
    return c.replace(
        num_layers=block * 2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        head_dim=head_dim,
        d_ff=256,
        vocab_size=512,
        window_size=min(c.window_size, 32) if c.window_size else 0,
        num_experts=min(c.num_experts, 4) if c.num_experts else 0,
        experts_per_token=min(c.experts_per_token, 2) if c.num_experts else 0,
        rwkv_head_dim=32,  # -> 4 rwkv heads at d_model=128
        ssm_state_dim=8,
        ssm_dt_rank=8,
        prefix_len=8 if c.prefix_len else 0,
        dtype="float32",
        param_dtype="float32",
        remat="none",
        num_microbatches=1,
        attn_chunk_q=16,
        attn_chunk_k=16,
        capacity_factor=2.0,
    )
