"""starcoder2-3b [dense] — GQA, RoPE, sliding-window 4096. [arXiv:2402.19173; hf]

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152. LayerNorm + biases,
non-gated GeLU MLP (4x), tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    attn_pattern=("local",),
    window_size=4096,
    mlp_type="gelu",
    norm_type="layernorm",
    norm_eps=1e-5,
    use_bias=True,
    rope_theta=100000.0,
    tie_embeddings=True,
    dtype="bfloat16",
    param_dtype="bfloat16",
    layout="cp_fsdp",
    remat="full",
    num_microbatches=2,
)
