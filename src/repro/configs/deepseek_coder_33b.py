"""deepseek-coder-33b [dense] — llama-arch GQA. [arXiv:2401.14196; hf]

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
56 heads / 8 kv-heads don't divide the 16-wide model axis, so the layout is
context-parallel attention + FSDP weight storage (DESIGN.md §5).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=100000.0,
    tie_embeddings=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
    layout="cp_fsdp",
    remat="full",
    num_microbatches=4,
)
