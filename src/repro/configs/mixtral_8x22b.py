"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768. ~141B total.
8 experts on a 16-wide model axis => expert-tensor-parallel MoE (each device
holds a 1/16 d_ff slice of every expert — see repro.models.mlp). 48 heads
divide 16, so attention uses Megatron-style head TP.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    attn_pattern=("local",),
    window_size=4096,
    moe_period=1,
    num_experts=8,
    experts_per_token=2,
    mlp_type="swiglu",
    norm_type="rmsnorm",
    rope_theta=1000000.0,
    tie_embeddings=False,
    dtype="bfloat16",
    param_dtype="bfloat16",
    layout="tp",
    remat="full",
    num_microbatches=8,
)
